"""Mamba2 (SSD — state-space duality) backbone in JAX.

Chunked SSD algorithm (Dao & Gu 2024, Listing 1): within-chunk quadratic
("attention-like") term + inter-chunk linear recurrence over chunk states.
Attention-free: there is no KV cache.  For blocked diffusion the analogue of
the paper's warm step is a full recompute that also *checkpoints the SSM
state at the active-block boundary*; refinement steps replay only the active
block from that state (causal SSM ⇒ suffix tokens cannot influence the
active block, so dual- and prefix-cache modes coincide — DESIGN.md §4).

BAOS inapplicability: there is no KV to quantize.  As a noted extension the
same warm-step calibration is applied to the *state* checkpoint before MX
quantization (cfg.baos reused), since the state plays the cache's role.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.core import baos as baos_lib
from repro.core import mx
from repro.models import layers
from repro.models.transformer import ModelConfig, _norm_params, _norm_specs, \
    _apply_norm

# SSD chunk length: must divide every segment length fed to the model
# (configs keep prompts/blocks multiples of this).
SSD_CHUNK = 16


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., l) -> (..., l, l) lower-triangular pairwise sums
    segsum[i, j] = sum_{k=j+1..i} a_k for i >= j, else -inf."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, h0: Optional[jax.Array] = None,
                chunk: int = SSD_CHUNK):
    """SSD scan.  x: (b,s,h,p), dt: (b,s,h), A: (h,), B,C: (b,s,g,n).

    Returns (y (b,s,h,p), chunk_states (b,nc+1,h,p,n)) where
    chunk_states[:, i] is the state at the *start* of chunk i (position i*Q),
    enabling block-boundary state capture for blocked diffusion.
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    if s % chunk:
        raise ValueError(f"seq {s} not a multiple of ssd chunk {chunk}")
    nc = s // chunk
    rep = h // g

    xd = (x * dt[..., None]).astype(jnp.float32)          # (b,s,h,p)
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)   # (b,s,h,n)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    a = (A[None, None, :] * dt).astype(jnp.float32)       # (b,s,h) = A*dt (<0)

    # chunked views (b, nc, Q, ...)
    def ch(t):
        return t.reshape(b, nc, chunk, *t.shape[2:])
    xc, Bc, Cc, ac = ch(xd), ch(Bh), ch(Ch), ch(a)
    ac_h = ac.transpose(0, 1, 3, 2)                       # (b,nc,h,Q)

    # ---- intra-chunk (quadratic) term --------------------------------------
    Lmat = jnp.exp(_segsum(ac_h))                         # (b,nc,h,Q,Q)
    y_intra = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp",
                         Cc, Bc, Lmat, xc)

    # ---- chunk states -------------------------------------------------------
    cum = jnp.cumsum(ac_h, axis=-1)                       # (b,nc,h,Q)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)           # (b,nc,h,Q)
    S_c = jnp.einsum("bcshn,bchs,bcshp->bchpn", Bc, decay_to_end, xc)

    # ---- inter-chunk recurrence --------------------------------------------
    chunk_decay = jnp.exp(cum[..., -1])                   # (b,nc,h)

    def body(state, inp):
        s_c, d_c = inp
        new = state * d_c[..., None, None] + s_c
        return new, state                                  # emit state at start

    init = (jnp.zeros((b, h, p, n), jnp.float32) if h0 is None
            else h0.astype(jnp.float32))
    final, starts = jax.lax.scan(
        body, init, (S_c.transpose(1, 0, 2, 3, 4),
                     chunk_decay.transpose(1, 0, 2)))
    starts = starts.transpose(1, 0, 2, 3, 4)              # (b,nc,h,p,n)
    all_states = jnp.concatenate([starts, final[:, None]], axis=1)

    decay_from_start = jnp.exp(cum)                       # (b,nc,h,Q)
    y_inter = jnp.einsum("bclhn,bchpn,bchl->bclhp",
                         Cc, starts, decay_from_start)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, all_states


def ssd_ref(x, dt, A, B, C, h0=None):
    """Sequential reference recurrence (oracle for tests)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xd = (x * dt[..., None]).astype(jnp.float32)
    a = jnp.exp((A[None, None, :] * dt).astype(jnp.float32))

    def step(hprev, t):
        hnew = hprev * a[:, t][..., None, None] + \
            xd[:, t][..., None] * Bh[:, t][..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", hnew, Ch[:, t])
        return hnew, y
    h0 = jnp.zeros((b, h, p, n), jnp.float32) if h0 is None else h0
    _, ys = jax.lax.scan(step, h0, jnp.arange(s))
    return ys.transpose(1, 0, 2, 3)


# ---------------------------------------------------------------------------
# Mamba2 block + model
# ---------------------------------------------------------------------------

def _mamba_dims(cfg: ModelConfig):
    d_inner = 2 * cfg.d_model
    headdim = cfg.ssm_head_dim
    nheads = d_inner // headdim
    ngroups = 1
    d_state = cfg.ssm_state
    conv_dim = d_inner + 2 * ngroups * d_state
    return d_inner, headdim, nheads, ngroups, d_state, conv_dim


def init_mamba_layer(key, cfg: ModelConfig):
    d_inner, hp, nh, ng, dn, conv_dim = _mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    d_in_proj = 2 * d_inner + 2 * ng * dn + nh
    dt = cfg.jdtype
    return {
        "norm": _norm_params(cfg.d_model, cfg.norm, dt),
        "in_proj": layers.dense_init(ks[0], cfg.d_model, d_in_proj, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_dim))
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "gate_norm": _norm_params(d_inner, "rms", dt),
        "out_proj": layers.dense_init(ks[2], d_inner, cfg.d_model, dt),
    }


def mamba_layer_specs(cfg: ModelConfig):
    return {
        "norm": _norm_specs(cfg.norm),
        "in_proj": ("embed", "mlp"), "conv_w": (None, "mlp"),
        "conv_b": ("mlp",), "A_log": (None,), "D": (None,),
        "dt_bias": (None,), "gate_norm": {"w": ("mlp",)},
        "out_proj": ("mlp", "embed"),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 conv_state: Optional[jax.Array] = None):
    """Depthwise causal conv, width W.  xbc: (B, S, C); w: (W, C).
    conv_state: (B, W-1, C) trailing inputs from the previous segment."""
    W = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i][None, None, :]
              for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else None
    return jax.nn.silu(out + b[None, None, :]), new_state


def mamba_block(x: jax.Array, lp, cfg: ModelConfig,
                h0: Optional[jax.Array] = None,
                conv_state: Optional[jax.Array] = None,
                chunk: int = SSD_CHUNK,
                capture_at: Optional[jax.Array] = None):
    """x: (B, S, d_model) -> (y, chunk_states, conv_state_at_capture).

    ``capture_at``: position at which to snapshot the trailing W-1 pre-conv
    rows (the conv state an active-block replay needs).
    """
    d_inner, hp, nh, ng, dn, conv_dim = _mamba_dims(cfg)
    B_, S, _ = x.shape
    W = cfg.conv_width
    zxbcdt = layers.qdot(x, lp["in_proj"], None)
    z, xbc_raw, dtv = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)
    conv_capture = None
    if capture_at is not None:
        start = jnp.maximum(capture_at - (W - 1), 0)
        conv_capture = jax.lax.dynamic_slice_in_dim(
            xbc_raw, start, W - 1, axis=1)
        conv_capture = jnp.where(capture_at >= W - 1, conv_capture, 0.0)
    xbc, _ = _causal_conv(xbc_raw, lp["conv_w"], lp["conv_b"], conv_state)
    xs, Bv, Cv = jnp.split(xbc, [d_inner, d_inner + ng * dn], axis=-1)
    xs = xs.reshape(B_, S, nh, hp)
    Bv = Bv.reshape(B_, S, ng, dn)
    Cv = Cv.reshape(B_, S, ng, dn)
    dt = jax.nn.softplus(dtv.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["A_log"])
    y, states = ssd_chunked(xs, dt, A, Bv, Cv, h0, chunk)
    y = y + lp["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B_, S, d_inner).astype(x.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z), lp["gate_norm"]["w"], cfg.norm_eps)
    return layers.qdot(y, lp["out_proj"], None), states, conv_capture


class MambaModel:
    """Mamba2 dLLM backbone with the transformer-compatible forward contract."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.chunk = SSD_CHUNK

    def init(self, key):
        cfg = self.cfg
        ke, kl, kh = jax.random.split(key, 3)
        lkeys = jax.random.split(kl, cfg.n_layers)
        return {
            "embed": layers.embed_init(ke, cfg.vocab, cfg.d_model, cfg.jdtype),
            "layers": jax.vmap(lambda k: init_mamba_layer(k, cfg))(lkeys),
            "final_norm": _norm_params(cfg.d_model, cfg.norm, cfg.jdtype),
            "lm_head": layers.dense_init(kh, cfg.d_model, cfg.vocab, cfg.jdtype),
        }

    def param_specs(self):
        cfg = self.cfg
        def stack(tree):
            return jax.tree.map(lambda s: ("layers",) + s, tree,
                                is_leaf=lambda x: isinstance(x, tuple))
        return {
            "embed": ("vocab", "embed"),
            "layers": stack(mamba_layer_specs(cfg)),
            "final_norm": _norm_specs(cfg.norm),
            "lm_head": ("embed", "vocab"),
        }

    def init_cache(self, batch: int, s_tot: int, act_len=None):
        # act_len (split attention cache) is inapplicable: no KV cache
        cfg = self.cfg
        d_inner, hp, nh, ng, dn, conv_dim = _mamba_dims(cfg)
        return {
            "state": jnp.zeros((cfg.n_layers, batch, nh, hp, dn), jnp.float32),
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1,
                               conv_dim), cfg.jdtype),
        }

    def cache_specs(self, act_len=None):
        return {"state": ("layers", "batch", "heads", None, None),
                "conv": ("layers", "batch", None, "mlp")}

    def forward(self, params, tokens=None, *, embeds=None, cache=None,
                seg_start=0, baos_cfg=None, calibrate=False, calib_mask=None,
                quant=None, kv_valid=None, logits_slice=None, **_):
        cfg = self.cfg
        if embeds is None:
            embeds = params["embed"][tokens] * cfg.embed_scale
        x = embeds.astype(cfg.jdtype)
        x = sharding.shard(x, "batch", "seq", "embed")

        warm = calibrate and cache is not None
        capture_at = (logits_slice[0] if (warm and logits_slice is not None)
                      else 0)
        capture_chunk = capture_at // self.chunk if warm else 0

        def layer_fn(carry, xs):
            x, = carry
            lp, lcache = xs
            h = _apply_norm(x, lp["norm"], cfg)
            if cache is None:
                y, _, _ = mamba_block(h, lp, cfg, None, None, self.chunk)
                new_lcache = 0
            elif warm:
                y, states, conv0 = mamba_block(
                    h, lp, cfg, None, None, self.chunk,
                    capture_at=jnp.asarray(capture_at, jnp.int32))
                s0 = jax.lax.dynamic_index_in_dim(
                    states, capture_chunk, axis=1, keepdims=False)
                if baos_cfg is not None and baos_cfg.enabled:
                    s0 = mx.mx_fake_quant(s0, baos_cfg.kv_format)
                new_lcache = {"state": s0,
                              "conv": conv0.astype(lcache["conv"].dtype)}
            else:
                y, _, _ = mamba_block(h, lp, cfg, lcache["state"],
                                      lcache["conv"], self.chunk)
                new_lcache = lcache
            return (x + y,), new_lcache

        if cfg.unroll_layers:
            new_caches = []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda t: t[i], params["layers"])
                lc = (jax.tree.map(lambda t: t[i], cache)
                      if cache is not None else None)
                (x,), nlc = layer_fn((x,), (lp, lc))
                new_caches.append(nlc)
            new_cache = (jax.tree.map(lambda *ls: jnp.stack(ls), *new_caches)
                         if cache is not None else None)
        else:
            (x,), new_cache = jax.lax.scan(
                layer_fn, (x,), (params["layers"], cache))
        x = _apply_norm(x, params["final_norm"], cfg)
        if logits_slice is not None:
            start, length = logits_slice
            x = jax.lax.dynamic_slice_in_dim(x, start, length, axis=1)
        logits = layers.qdot(x, params["lm_head"], quant) * cfg.logit_scale
        logits = sharding.shard(logits, "batch", "seq", "vocab")
        if cache is None:
            new_cache = None
        return logits, new_cache, jnp.float32(0)

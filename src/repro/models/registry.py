"""Model registry: family -> Model class with the shared contract.

Every model exposes:
    cfg                          ModelConfig
    init(key) -> params
    param_specs() -> pytree of logical-axis tuples (matches params)
    init_cache(batch, s_tot) -> cache pytree
    cache_specs() -> pytree of logical-axis tuples (matches cache)
    forward(params, tokens, *, cache, seg_start, baos_cfg, calibrate,
            calib_mask, quant, kv_valid, logits_slice, ...) ->
        (logits, new_cache, aux_loss)

Models that can stop before the LM head set ``supports_head_mode = True``:
their forward accepts ``head_mode='hidden'`` (returning final-norm hidden
states) and their params expose the head weights at ``params['lm_head']``
(shape (d_model, vocab)) — the contract the fused head + Stable-Max
sampling path (core/diffusion, core/sampling) relies on.
"""
from __future__ import annotations

from repro.models import transformer
from repro.models.transformer import ModelConfig


class TransformerModel:
    """Dense / MoE dLLM (also the VLM/audio text-decoder base)."""

    supports_head_mode = True        # forward(head_mode="hidden") works

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key):
        return transformer.init_params(key, self.cfg)

    def param_specs(self):
        return transformer.param_specs(self.cfg)

    def init_cache(self, batch: int, s_tot: int, act_len=None):
        return transformer.init_cache(self.cfg, batch, s_tot, act_len)

    def cache_specs(self, act_len=None):
        return transformer.cache_specs(self.cfg, act_len)

    def forward(self, params, tokens=None, **kw):
        return transformer.forward(params, self.cfg, tokens, **kw)


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe"):
        return TransformerModel(cfg)
    if cfg.family == "ssm":
        from repro.models.ssm import MambaModel
        return MambaModel(cfg)
    if cfg.family == "hybrid":
        from repro.models.rglru import GriffinModel
        return GriffinModel(cfg)
    if cfg.family == "audio":
        from repro.models.whisper import WhisperModel
        return WhisperModel(cfg)
    if cfg.family == "vlm":
        from repro.models.vlm import VLMModel
        return VLMModel(cfg)
    raise ValueError(f"unknown model family {cfg.family!r}")

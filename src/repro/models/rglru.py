"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention.

Layer pattern is (rec, rec, attn) repeated (paper: "RG-LRU + local attn,
1:2") — 26 layers = 8 triples + 2 trailing recurrent layers.  The stack scans
over the 8 triples (one triple's HLO regardless of depth) and unrolls the
tail.

Blocked-diffusion semantics mirror the dense model for the *attention*
layers (windowed KV cache, BAOS-smoothed) and the SSM model for the
*recurrent* layers (warm step checkpoints the RG-LRU hidden state + conv
state at the active-block boundary; refinement replays the block from it).
`long_500k` runs on this arch: the local window (2048) and the fixed-size
recurrent state make it sub-quadratic.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.core import baos as baos_lib
from repro.models import layers
from repro.models.transformer import (ModelConfig, _norm_params, _norm_specs,
                                      _apply_norm)

RGLRU_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU recurrence
# ---------------------------------------------------------------------------

def rglru_scan(x: jax.Array, r: jax.Array, i: jax.Array, lam: jax.Array,
               h0: Optional[jax.Array] = None):
    """x, r, i: (B, S, D); lam: (D,) learnable Λ.
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t ⊙ x_t),
    a_t = exp(-c softplus(Λ) r_t).  Returns (h_all (B,S,D))."""
    log_a = -RGLRU_C * jax.nn.softplus(lam)[None, None, :] * \
        r.astype(jnp.float32)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * \
        (i.astype(jnp.float32) * x.astype(jnp.float32))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a2 * a1, a2 * b1 + b2

    sa, sb = jax.lax.associative_scan(combine, (a, b), axis=1)
    if h0 is not None:
        h = sb + sa * h0[:, None, :].astype(jnp.float32)
    else:
        h = sb
    return h


def rglru_ref(x, r, i, lam, h0=None):
    """Sequential oracle."""
    log_a = -RGLRU_C * jax.nn.softplus(lam)[None, :]
    def step(h, t):
        a = jnp.exp(log_a * r[:, t].astype(jnp.float32))
        b = jnp.sqrt(jnp.maximum(1 - a * a, 0)) * \
            (i[:, t] * x[:, t]).astype(jnp.float32)
        h = a * h + b
        return h, h
    B, S, D = x.shape
    h0 = jnp.zeros((B, D), jnp.float32) if h0 is None else h0
    _, hs = jax.lax.scan(step, h0, jnp.arange(S))
    return hs.transpose(1, 0, 2)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def init_rec_block(key, cfg: ModelConfig):
    d, dr = cfg.d_model, cfg.d_rnn
    ks = jax.random.split(key, 6)
    dt = cfg.jdtype
    return {
        "w_y": layers.dense_init(ks[0], d, dr, dt),
        "w_gate": layers.dense_init(ks[1], d, dr, dt),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, dr)) * 0.1
                   ).astype(dt),
        "conv_b": jnp.zeros((dr,), dt),
        "w_a": layers.dense_init(ks[3], dr, dr, dt),
        "b_a": jnp.zeros((dr,), dt),
        "w_x": layers.dense_init(ks[4], dr, dr, dt),
        "b_x": jnp.zeros((dr,), dt),
        "lam": jnp.full((dr,), 0.7, jnp.float32),
        "w_out": layers.dense_init(ks[5], dr, d, dt),
    }


def rec_block_specs():
    return {"w_y": ("embed", "mlp"), "w_gate": ("embed", "mlp"),
            "conv_w": (None, "mlp"), "conv_b": ("mlp",),
            "w_a": ("mlp", None), "b_a": ("mlp",),
            "w_x": ("mlp", None), "b_x": ("mlp",),
            "lam": ("mlp",), "w_out": ("mlp", "embed")}


def _causal_conv1d(x, w, b, conv_state):
    W = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, k:k + x.shape[1]] * w[k][None, None, :] for k in range(W))
    return out + b[None, None, :]


def rec_block(x, p, cfg: ModelConfig, h0=None, conv_state=None,
              capture_at: Optional[jax.Array] = None):
    """Griffin recurrent temporal block.  x: (B, S, d_model) (pre-normed).
    Returns (y, h_capture (B,Dr) | None, conv_capture | None)."""
    W = cfg.conv_width
    y = layers.qdot(x, p["w_y"], None)
    gate = jax.nn.gelu(layers.qdot(x, p["w_gate"], None))

    h_cap = conv_cap = None
    if capture_at is not None:
        start = jnp.maximum(capture_at - (W - 1), 0)
        conv_cap = jax.lax.dynamic_slice_in_dim(y, start, W - 1, axis=1)
        conv_cap = jnp.where(capture_at >= W - 1, conv_cap, 0.0)
    y = _causal_conv1d(y, p["conv_w"], p["conv_b"], conv_state)

    r = jax.nn.sigmoid(layers.qdot(y, p["w_a"], None, p["b_a"]))
    i = jax.nn.sigmoid(layers.qdot(y, p["w_x"], None, p["b_x"]))
    h = rglru_scan(y, r, i, p["lam"], h0)
    if capture_at is not None:
        idx = jnp.maximum(capture_at - 1, 0)
        h_cap = jax.lax.dynamic_index_in_dim(h, idx, axis=1, keepdims=False)
        h_cap = jnp.where(capture_at >= 1, h_cap, 0.0)
    out = layers.qdot((h.astype(x.dtype) * gate), p["w_out"], None)
    return out, h_cap, conv_cap


def init_attn_block(key, cfg: ModelConfig):
    d = cfg.d_model
    hq, hkv = cfg.n_heads * cfg.d_head, cfg.n_kv_heads * cfg.d_head
    ks = jax.random.split(key, 4)
    dt = cfg.jdtype
    return {"wq": layers.dense_init(ks[0], d, hq, dt),
            "wk": layers.dense_init(ks[1], d, hkv, dt),
            "wv": layers.dense_init(ks[2], d, hkv, dt),
            "wo": layers.dense_init(ks[3], hq, d, dt)}


def attn_block_specs():
    return {"wq": ("embed", "heads"), "wk": ("embed", "heads"),
            "wv": ("embed", "heads"), "wo": ("heads", "embed")}


def init_mlp(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    dt = cfg.jdtype
    return {"w_gate": layers.dense_init(ks[0], cfg.d_model, cfg.d_ff, dt),
            "w_up": layers.dense_init(ks[1], cfg.d_model, cfg.d_ff, dt),
            "w_down": layers.dense_init(ks[2], cfg.d_ff, cfg.d_model, dt)}


def _geglu_mlp(x, p):
    h = jax.nn.gelu(layers.qdot(x, p["w_gate"], None)) * \
        layers.qdot(x, p["w_up"], None)
    return layers.qdot(h, p["w_down"], None)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class GriffinModel:
    """8 scanned (rec, rec, attn) triples + 2 tail rec layers (26 total)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        if cfg.n_layers % 3 != 2:
            raise ValueError(
                f"expect 3k+2 layers (rec,rec,attn)*k + 2; "
                f"got n_layers={cfg.n_layers}")
        self.n_triples = cfg.n_layers // 3

    # -- params ------------------------------------------------------------
    def _init_sub(self, key, kind):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        temporal = (init_rec_block(k1, cfg) if kind == "rec"
                    else init_attn_block(k1, cfg))
        return {"ln1": _norm_params(cfg.d_model, cfg.norm, cfg.jdtype),
                "ln2": _norm_params(cfg.d_model, cfg.norm, cfg.jdtype),
                "temporal": temporal, "mlp": init_mlp(k3, cfg)}

    def _sub_specs(self, kind):
        cfg = self.cfg
        t = rec_block_specs() if kind == "rec" else attn_block_specs()
        return {"ln1": _norm_specs(cfg.norm), "ln2": _norm_specs(cfg.norm),
                "temporal": t,
                "mlp": {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
                        "w_down": ("mlp", "embed")}}

    def init(self, key):
        cfg = self.cfg
        ke, kt, ktl, kh = jax.random.split(key, 4)
        tkeys = jax.random.split(kt, self.n_triples)

        def init_triple(k):
            ka, kb, kc = jax.random.split(k, 3)
            return {"rec1": self._init_sub(ka, "rec"),
                    "rec2": self._init_sub(kb, "rec"),
                    "attn": self._init_sub(kc, "attn")}

        tail_keys = jax.random.split(ktl, 2)
        return {
            "embed": layers.embed_init(ke, cfg.vocab, cfg.d_model, cfg.jdtype),
            "triples": jax.vmap(init_triple)(tkeys),
            "tail": jax.vmap(lambda k: self._init_sub(k, "rec"))(tail_keys),
            "final_norm": _norm_params(cfg.d_model, cfg.norm, cfg.jdtype),
            "lm_head": layers.dense_init(kh, cfg.d_model, cfg.vocab,
                                         cfg.jdtype),
        }

    def param_specs(self):
        def stack(tree):
            return jax.tree.map(lambda s: ("layers",) + s, tree,
                                is_leaf=lambda x: isinstance(x, tuple))
        return {
            "embed": ("vocab", "embed"),
            "triples": stack({"rec1": self._sub_specs("rec"),
                              "rec2": self._sub_specs("rec"),
                              "attn": self._sub_specs("attn")}),
            "tail": stack(self._sub_specs("rec")),
            "final_norm": _norm_specs(self.cfg.norm),
            "lm_head": ("embed", "vocab"),
        }

    # -- cache ---------------------------------------------------------------
    def init_cache(self, batch: int, s_tot: int, act_len=None):
        # act_len (split attention cache) not yet applied to the hybrid
        cfg = self.cfg
        nt = self.n_triples
        kv = (nt, batch, s_tot, cfg.n_kv_heads, cfg.d_head)
        cal = (nt, batch, 1, cfg.n_kv_heads, cfg.d_head)
        rec = (nt, 2, batch, cfg.d_rnn)
        cw = (nt, 2, batch, cfg.conv_width - 1, cfg.d_rnn)
        return {
            "k": jnp.zeros(kv, cfg.jdtype), "v": jnp.zeros(kv, cfg.jdtype),
            "k_center": jnp.zeros(cal, jnp.float32),
            "k_scale": jnp.ones(cal, jnp.float32),
            "v_center": jnp.zeros(cal, jnp.float32),
            "v_scale": jnp.ones(cal, jnp.float32),
            "rec_state": jnp.zeros(rec, jnp.float32),
            "rec_conv": jnp.zeros(cw, cfg.jdtype),
            "tail_state": jnp.zeros((2, batch, cfg.d_rnn), jnp.float32),
            "tail_conv": jnp.zeros((2, batch, cfg.conv_width - 1, cfg.d_rnn),
                                   cfg.jdtype),
        }

    def cache_specs(self, act_len=None):
        kv = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
        cal = ("layers", "batch", None, "kv_heads", "head_dim")
        return {"k": kv, "v": kv, "k_center": cal, "k_scale": cal,
                "v_center": cal, "v_scale": cal,
                "rec_state": ("layers", None, "batch", "mlp"),
                "rec_conv": ("layers", None, "batch", None, "mlp"),
                "tail_state": (None, "batch", "mlp"),
                "tail_conv": (None, "batch", None, "mlp")}

    # -- forward -------------------------------------------------------------
    def _rec_sub(self, x, p, st):
        """st: dict(h0, conv, capture_at) or None (stateless)."""
        cfg = self.cfg
        h = _apply_norm(x, p["ln1"], cfg)
        if st is None:
            y, hc, cc = rec_block(h, p["temporal"], cfg)
        else:
            y, hc, cc = rec_block(h, p["temporal"], cfg, st.get("h0"),
                                  st.get("conv"), st.get("capture_at"))
        x = x + y
        x = x + _geglu_mlp(_apply_norm(x, p["ln2"], cfg), p["mlp"])
        return x, hc, cc

    def _attn_sub(self, x, p, lk, lv, lcal, *, seg_start, positions, kv_pos,
                  kv_valid, baos_cfg, calibrate, calib_mask):
        cfg = self.cfg
        B, S, _ = x.shape
        h = _apply_norm(x, p["ln1"], cfg)
        ap = p["temporal"]
        q = layers.qdot(h, ap["wq"], None).reshape(B, S, cfg.n_heads,
                                                   cfg.d_head)
        k = layers.qdot(h, ap["wk"], None).reshape(B, S, cfg.n_kv_heads,
                                                   cfg.d_head)
        v = layers.qdot(h, ap["wv"], None).reshape(B, S, cfg.n_kv_heads,
                                                   cfg.d_head)
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, positions, cfg.rope_theta)

        if lk is not None:
            if calibrate:
                calib = baos_lib.calibrate(k, v, baos_cfg, calib_mask)
            else:
                calib = lcal
            if baos_cfg.enabled:
                ks, vs = baos_lib.smooth_quantize_kv(k, v, calib, baos_cfg)
                use_cal = calib
            else:
                ks, vs, use_cal = k, v, None
            zero = jnp.zeros((), jnp.int32)
            lk = jax.lax.dynamic_update_slice(
                lk, ks.astype(lk.dtype), (zero, seg_start, zero, zero))
            lv = jax.lax.dynamic_update_slice(
                lv, vs.astype(lv.dtype), (zero, seg_start, zero, zero))
            attn_out = layers.attention(
                q, lk, lv, q_pos=positions, kv_pos=kv_pos, kv_valid=kv_valid,
                mode="bidir", window=cfg.window, baos_calib=use_cal,
                kv_chunk=cfg.attn_chunk, unroll=cfg.unroll_layers)
        else:
            calib = None
            attn_out = layers.attention(
                q, k, v, q_pos=positions, kv_pos=positions,
                kv_valid=jnp.ones((B, S), bool), mode="bidir",
                window=cfg.window, kv_chunk=cfg.attn_chunk,
                unroll=cfg.unroll_layers)
        attn_out = attn_out.reshape(B, S, cfg.n_heads * cfg.d_head)
        x = x + layers.qdot(attn_out, ap["wo"], None)
        x = x + _geglu_mlp(_apply_norm(x, p["ln2"], cfg), p["mlp"])
        return x, lk, lv, calib

    def forward(self, params, tokens=None, *, embeds=None, cache=None,
                seg_start=0, kv_valid=None, baos_cfg=None, calibrate=False,
                calib_mask=None, quant=None, logits_slice=None, **_):
        cfg = self.cfg
        baos_cfg = baos_cfg or baos_lib.BAOSConfig(enabled=False)
        if embeds is None:
            embeds = params["embed"][tokens] * cfg.embed_scale
        x = embeds.astype(cfg.jdtype)
        B, S = x.shape[:2]
        if isinstance(seg_start, int):
            seg_start = jnp.int32(seg_start)
        positions = jnp.broadcast_to(
            seg_start + jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))

        warm = calibrate and cache is not None
        capture_at = (logits_slice[0] if (warm and logits_slice is not None)
                      else jnp.int32(0))

        if cache is not None:
            s_tot = cache["k"].shape[2]
            kv_pos = jnp.broadcast_to(
                jnp.arange(s_tot, dtype=jnp.int32)[None, :], (B, s_tot))
            if kv_valid is None:
                kv_valid = jnp.ones((B, s_tot), bool)
        else:
            kv_pos, kv_valid = positions, jnp.ones((B, S), bool)

        def rec_state(lstate, lconv):
            if cache is None:
                return None
            if warm:
                return {"capture_at": jnp.asarray(capture_at, jnp.int32)}
            return {"h0": lstate, "conv": lconv}

        def triple_fn(carry, xs):
            x, = carry
            tp, tc = xs
            new_tc = dict(tc) if tc is not None else None
            for j, name in enumerate(("rec1", "rec2")):
                st = rec_state(tc["rec_state"][j] if tc else None,
                               tc["rec_conv"][j] if tc else None)
                x, hc, cc = self._rec_sub(x, tp[name], st)
                if warm:
                    new_tc["rec_state"] = new_tc["rec_state"].at[j].set(hc)
                    new_tc["rec_conv"] = new_tc["rec_conv"].at[j].set(
                        cc.astype(new_tc["rec_conv"].dtype))
            if tc is not None:
                lcal = baos_lib.BAOSCalib(tc["k_center"], tc["k_scale"],
                                          tc["v_center"], tc["v_scale"])
                x, lk, lv, calib = self._attn_sub(
                    x, tp["attn"], tc["k"], tc["v"], lcal,
                    seg_start=seg_start, positions=positions, kv_pos=kv_pos,
                    kv_valid=kv_valid, baos_cfg=baos_cfg, calibrate=calibrate,
                    calib_mask=calib_mask)
                new_tc["k"], new_tc["v"] = lk, lv
                if calibrate and calib is not None:
                    new_tc.update({"k_center": calib.k_center,
                                   "k_scale": calib.k_scale,
                                   "v_center": calib.v_center,
                                   "v_scale": calib.v_scale})
            else:
                x, _, _, _ = self._attn_sub(
                    x, tp["attn"], None, None, None,
                    seg_start=seg_start, positions=positions, kv_pos=kv_pos,
                    kv_valid=kv_valid, baos_cfg=baos_cfg, calibrate=False,
                    calib_mask=None)
                new_tc = 0
            return (x,), new_tc

        tcache = None
        if cache is not None:
            tcache = {k: cache[k] for k in
                      ("k", "v", "k_center", "k_scale", "v_center", "v_scale",
                       "rec_state", "rec_conv")}
        if cfg.unroll_layers:
            new_ts = []
            for i in range(self.n_triples):
                tp = jax.tree.map(lambda t: t[i], params["triples"])
                tc = (jax.tree.map(lambda t: t[i], tcache)
                      if tcache is not None else None)
                (x,), ntc = triple_fn((x,), (tp, tc))
                new_ts.append(ntc)
            new_tcache = (jax.tree.map(lambda *ls: jnp.stack(ls), *new_ts)
                          if tcache is not None else 0)
        else:
            (x,), new_tcache = jax.lax.scan(
                triple_fn, (x,), (params["triples"], tcache))

        new_cache = None
        if cache is not None:
            new_cache = dict(new_tcache)
            new_cache["tail_state"] = cache["tail_state"]
            new_cache["tail_conv"] = cache["tail_conv"]

        for j in range(2):
            tp = jax.tree.map(lambda t: t[j], params["tail"])
            st = rec_state(cache["tail_state"][j] if cache is not None else None,
                           cache["tail_conv"][j] if cache is not None else None)
            x, hc, cc = self._rec_sub(x, tp, st)
            if warm:
                new_cache["tail_state"] = new_cache["tail_state"].at[j].set(hc)
                new_cache["tail_conv"] = new_cache["tail_conv"].at[j].set(
                    cc.astype(new_cache["tail_conv"].dtype))

        x = _apply_norm(x, params["final_norm"], cfg)
        if logits_slice is not None:
            start, length = logits_slice
            x = jax.lax.dynamic_slice_in_dim(x, start, length, axis=1)
        logits = layers.qdot(x, params["lm_head"], quant) * cfg.logit_scale
        logits = sharding.shard(logits, "batch", "seq", "vocab")
        return logits, new_cache, jnp.float32(0)

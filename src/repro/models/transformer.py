"""dLLM transformer stack (dense | MoE | decoder-with-cross-attention).

Design points:
  * **scan-over-layers** with stacked per-layer params: one layer's HLO is
    compiled once regardless of depth (essential for 512-device dry-runs).
  * **blocked-diffusion KV cache**: a full-length (B, S_tot, H_kv, D) buffer
    per layer, refreshed in place by `lax.dynamic_update_slice` — the
    non-append-only pattern the paper builds hardware for.
  * **BAOS** (paper §4.4): the cache stores *smoothed+MX-quantized* KV; the
    per-generation-block calibration is computed during the warm step and
    threaded through the cache pytree; attention consumes the smoothed cache
    with the Q-fusion identities.
  * bidirectional attention throughout (mask_mode="bidir"), optional local
    window and causal modes for the hybrid/AR-baseline paths.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.core import baos as baos_lib
from repro.models import layers
from repro.models.layers import QuantPolicy
from repro.models import moe as moe_lib


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    norm: str = "rms"               # rms | ln
    ffn: str = "swiglu"             # swiglu | gelu
    mask_token_id: Optional[int] = None   # defaults to vocab - 1
    moe: Optional[moe_lib.MoEConfig] = None
    window: Optional[int] = None    # local attention window (all attn layers)
    attn_mode: str = "bidir"        # bidir | causal
    # hybrid (recurrentgemma): layer pattern, d_rnn; ssm (mamba2) extras
    block_pattern: Optional[Tuple[str, ...]] = None   # e.g. ("rec","rec","attn")
    d_rnn: int = 0
    ssm_state: int = 0
    ssm_head_dim: int = 64
    conv_width: int = 4
    # enc-dec (whisper)
    n_encoder_layers: int = 0
    n_audio_ctx: int = 1500
    # vlm
    n_image_tokens: int = 0
    # scaling knobs (minicpm mu-param)
    embed_scale: float = 1.0
    residual_scale: float = 1.0
    logit_scale: float = 1.0
    # execution
    dtype: str = "bfloat16"
    attn_chunk: int = 1024
    remat: str = "none"             # none | full | dots
    sub_quadratic: bool = False     # long_500k eligibility
    unroll_layers: bool = False     # python-loop layers instead of lax.scan
    #                                 (XLA cost_analysis counts a while body
    #                                 once; the dry-run's cost variants
    #                                 unroll to get true per-layer costs)
    score_dtype: str = "float32"    # attention score/prob dtype (bfloat16 =
    #                                 §Perf hillclimb: halves score traffic)

    @property
    def jscore_dtype(self):
        return jnp.dtype(self.score_dtype)

    @property
    def mask_id(self) -> int:
        return self.mask_token_id if self.mask_token_id is not None \
            else self.vocab - 1

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        d, h = self.d_model, self.n_heads * self.d_head
        hkv = self.n_kv_heads * self.d_head
        attn = d * h + 2 * d * hkv + h * d
        if self.moe is not None:
            m = self.moe
            ff = m.num_experts * 3 * d * m.d_ff_expert + d * m.num_experts
            ff += 3 * d * (m.d_ff_shared or m.num_shared_experts * m.d_ff_expert)
        else:
            ff = 3 * d * self.d_ff if self.ffn == "swiglu" else 2 * d * self.d_ff
        per_layer = attn + ff + 2 * d
        return (self.n_layers * per_layer + 2 * self.vocab * d + d)

    def active_param_count(self) -> int:
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        ff_all = m.num_experts * 3 * d * m.d_ff_expert
        ff_act = m.top_k * 3 * d * m.d_ff_expert
        return self.param_count() - self.n_layers * (ff_all - ff_act)


# ---------------------------------------------------------------------------
# Parameter init / specs
# ---------------------------------------------------------------------------

def _norm_params(d: int, norm: str, dtype):
    if norm == "ln":
        return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    return {"w": jnp.ones((d,), dtype)}


def _norm_specs(norm: str):
    if norm == "ln":
        return {"w": ("embed",), "b": ("embed",)}
    return {"w": ("embed",)}


def _apply_norm(x, p, cfg: ModelConfig):
    if cfg.norm == "ln":
        return layers.layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return layers.rms_norm(x, p["w"], cfg.norm_eps)


def init_layer_params(key: jax.Array, cfg: ModelConfig, cross_attn: bool = False):
    ks = jax.random.split(key, 12)
    d = cfg.d_model
    hq, hkv = cfg.n_heads * cfg.d_head, cfg.n_kv_heads * cfg.d_head
    dt = cfg.jdtype
    p: Dict[str, Any] = {
        "ln1": _norm_params(d, cfg.norm, dt),
        "ln2": _norm_params(d, cfg.norm, dt),
        "attn": {
            "wq": layers.dense_init(ks[0], d, hq, dt),
            "wk": layers.dense_init(ks[1], d, hkv, dt),
            "wv": layers.dense_init(ks[2], d, hkv, dt),
            "wo": layers.dense_init(ks[3], hq, d, dt),
        },
    }
    if cfg.qkv_bias:
        p["attn"]["bq"] = jnp.zeros((hq,), dt)
        p["attn"]["bk"] = jnp.zeros((hkv,), dt)
        p["attn"]["bv"] = jnp.zeros((hkv,), dt)
    if cross_attn:
        p["ln_x"] = _norm_params(d, cfg.norm, dt)
        p["xattn"] = {
            "wq": layers.dense_init(ks[8], d, hq, dt),
            "wk": layers.dense_init(ks[9], d, hkv, dt),
            "wv": layers.dense_init(ks[10], d, hkv, dt),
            "wo": layers.dense_init(ks[11], hq, d, dt),
        }
    if cfg.moe is not None:
        p["moe"] = moe_lib.init_moe_params(ks[4], d, cfg.moe, dt)
    elif cfg.ffn == "swiglu":
        p["mlp"] = {
            "w_gate": layers.dense_init(ks[5], d, cfg.d_ff, dt),
            "w_up": layers.dense_init(ks[6], d, cfg.d_ff, dt),
            "w_down": layers.dense_init(ks[7], cfg.d_ff, d, dt),
        }
    else:
        p["mlp"] = {
            "w_in": layers.dense_init(ks[5], d, cfg.d_ff, dt),
            "b_in": jnp.zeros((cfg.d_ff,), dt),
            "w_out": layers.dense_init(ks[7], cfg.d_ff, d, dt),
            "b_out": jnp.zeros((d,), dt),
        }
    return p


def layer_param_specs(cfg: ModelConfig, cross_attn: bool = False):
    p: Dict[str, Any] = {
        "ln1": _norm_specs(cfg.norm), "ln2": _norm_specs(cfg.norm),
        "attn": {"wq": ("embed", "heads"), "wk": ("embed", "heads"),
                 "wv": ("embed", "heads"), "wo": ("heads", "embed")},
    }
    if cfg.qkv_bias:
        p["attn"].update({"bq": ("heads",), "bk": ("heads",), "bv": ("heads",)})
    if cross_attn:
        p["ln_x"] = _norm_specs(cfg.norm)
        p["xattn"] = dict(p["attn"])
        p["xattn"] = {"wq": ("embed", "heads"), "wk": ("embed", "heads"),
                      "wv": ("embed", "heads"), "wo": ("heads", "embed")}
    if cfg.moe is not None:
        p["moe"] = moe_lib.moe_param_specs(cfg.moe)
    elif cfg.ffn == "swiglu":
        p["mlp"] = {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
                    "w_down": ("mlp", "embed")}
    else:
        p["mlp"] = {"w_in": ("embed", "mlp"), "b_in": ("mlp",),
                    "w_out": ("mlp", "embed"), "b_out": ("embed",)}
    return p


def init_params(key: jax.Array, cfg: ModelConfig, cross_attn: bool = False):
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    lkeys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer_params(k, cfg, cross_attn))(lkeys)
    return {
        "embed": layers.embed_init(k_embed, cfg.vocab, cfg.d_model, cfg.jdtype),
        "layers": stacked,
        "final_norm": _norm_params(cfg.d_model, cfg.norm, cfg.jdtype),
        "lm_head": layers.dense_init(k_head, cfg.d_model, cfg.vocab, cfg.jdtype),
    }


def param_specs(cfg: ModelConfig, cross_attn: bool = False):
    def stack(tree):
        return jax.tree.map(lambda s: ("layers",) + s, tree,
                            is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embed": ("vocab", "embed"),
        "layers": stack(layer_param_specs(cfg, cross_attn)),
        "final_norm": _norm_specs(cfg.norm),
        "lm_head": ("embed", "vocab"),
    }


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, s_tot: int,
               act_len: Optional[int] = None):
    """Full-length refreshable KV buffer + stacked BAOS calibration.

    ``act_len`` enables the SPLIT layout (§Perf): refinement steps write a
    small replicated active-block buffer (k_act/v_act) instead of a
    dynamic-update-slice into the sharded full-length buffer — the DART
    'active block stays in SRAM' execution model.  The active buffer holds
    *smoothed-but-unquantized* KV so one softmax spans both sources exactly
    (same center/scale space; DESIGN.md §7).
    """
    shape = (cfg.n_layers, batch, s_tot, cfg.n_kv_heads, cfg.d_head)
    cal = (cfg.n_layers, batch, 1, cfg.n_kv_heads, cfg.d_head)
    cache = {
        "k": jnp.zeros(shape, cfg.jdtype),
        "v": jnp.zeros(shape, cfg.jdtype),
        "k_center": jnp.zeros(cal, jnp.float32),
        "k_scale": jnp.ones(cal, jnp.float32),
        "v_center": jnp.zeros(cal, jnp.float32),
        "v_scale": jnp.ones(cal, jnp.float32),
    }
    if act_len is not None:
        act = (cfg.n_layers, batch, act_len, cfg.n_kv_heads, cfg.d_head)
        cache["k_act"] = jnp.zeros(act, cfg.jdtype)
        cache["v_act"] = jnp.zeros(act, cfg.jdtype)
    return cache


def cache_specs(cfg: ModelConfig, act_len: Optional[int] = None):
    kv = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    cal = ("layers", "batch", None, "kv_heads", "head_dim")
    spec = {"k": kv, "v": kv, "k_center": cal, "k_scale": cal,
            "v_center": cal, "v_scale": cal}
    if act_len is not None:
        act = ("layers", "batch", None, "kv_heads", "head_dim")
        spec["k_act"] = act
        spec["v_act"] = act
    return spec


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _qkv(x, ap, cfg: ModelConfig, quant, positions):
    B, S, _ = x.shape
    q = layers.qdot(x, ap["wq"], quant, ap.get("bq"))
    k = layers.qdot(x, ap["wk"], quant, ap.get("bk"))
    v = layers.qdot(x, ap["wv"], quant, ap.get("bv"))
    q = q.reshape(B, S, cfg.n_heads, cfg.d_head)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    if cfg.rope_theta > 0:
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, positions, cfg.rope_theta)
    return q, k, v


def _ffn(x, lp, cfg: ModelConfig, quant):
    if cfg.moe is not None:
        return moe_lib.moe_ffn(x, lp["moe"], cfg.moe, quant)
    mp = lp["mlp"]
    if cfg.ffn == "swiglu":
        h = layers.swiglu(layers.qdot(x, mp["w_gate"], quant),
                          layers.qdot(x, mp["w_up"], quant))
        return layers.qdot(h, mp["w_down"], quant), jnp.float32(0)
    h = jax.nn.gelu(layers.qdot(x, mp["w_in"], quant, mp["b_in"]))
    return layers.qdot(h, mp["w_out"], quant, mp["b_out"]), jnp.float32(0)


def _layer(x, lp, lcache, cfg: ModelConfig, *, seg_start, positions,
           kv_valid, kv_pos, baos_cfg: baos_lib.BAOSConfig,
           calibrate: bool, calib_mask, quant, cross_kv=None, attn_mode=None,
           calib_start=None):
    """One transformer layer over a segment; returns (x, new_layer_cache, aux)."""
    B, S, _ = x.shape
    mode = attn_mode or cfg.attn_mode

    h = _apply_norm(x, lp["ln1"], cfg)
    h = sharding.shard(h, "batch", "seq", "embed")
    q, k_seg, v_seg = _qkv(h, lp["attn"], cfg, quant, positions)
    q = sharding.shard(q, "batch", "seq", "heads", None)

    aux = jnp.float32(0)
    if lcache is not None:
        split = "k_act" in lcache
        if calibrate:
            calib = baos_lib.calibrate(k_seg, v_seg, baos_cfg, calib_mask)
        else:
            calib = baos_lib.BAOSCalib(
                lcache["k_center"], lcache["k_scale"],
                lcache["v_center"], lcache["v_scale"])
        if baos_cfg.enabled:
            use_calib = calib
        else:
            use_calib = None
        zero = jnp.zeros((), jnp.int32)
        new_cache = {"k_center": calib.k_center, "k_scale": calib.k_scale,
                     "v_center": calib.v_center, "v_scale": calib.v_scale}

        if split and not calibrate:
            # SPLIT refinement: smoothed-unquantized active buffer only;
            # the sharded full-length buffer is read-only (no DUS).
            if baos_cfg.enabled:
                ks_act = (k_seg.astype(jnp.float32) - calib.k_center) / \
                    calib.k_scale
                vs_act = (v_seg.astype(jnp.float32) - calib.v_center) / \
                    calib.v_scale
            else:
                ks_act, vs_act = k_seg, v_seg
            L_act = lcache["k_act"].shape[1]
            act_pos = positions[:, :L_act]
            act_valid = jnp.ones(act_pos.shape, bool)
            # the stale copy of the active block inside the big buffer is
            # masked out of the softmax
            in_act = (kv_pos >= seg_start) & (kv_pos < seg_start + L_act)
            attn_out = layers.attention(
                q, lcache["k"], lcache["v"], q_pos=positions, kv_pos=kv_pos,
                kv_valid=kv_valid & ~in_act, mode=mode, window=cfg.window,
                baos_calib=use_calib, kv_chunk=cfg.attn_chunk,
                unroll=cfg.unroll_layers, score_dtype=cfg.jscore_dtype,
                extra_kv=(ks_act.astype(cfg.jdtype),
                          vs_act.astype(cfg.jdtype), act_pos, act_valid))
            new_cache.update({
                "k": lcache["k"], "v": lcache["v"],
                "k_act": ks_act.astype(lcache["k_act"].dtype),
                "v_act": vs_act.astype(lcache["v_act"].dtype)})
        else:
            if baos_cfg.enabled:
                ks, vs = baos_lib.smooth_quantize_kv(k_seg, v_seg, calib,
                                                     baos_cfg)
            else:
                ks, vs = k_seg, v_seg
            new_k = jax.lax.dynamic_update_slice(
                lcache["k"], ks.astype(lcache["k"].dtype),
                (zero, seg_start, zero, zero))
            new_v = jax.lax.dynamic_update_slice(
                lcache["v"], vs.astype(lcache["v"].dtype),
                (zero, seg_start, zero, zero))
            new_k = sharding.shard(new_k, "batch", "kv_seq", "kv_heads",
                                   None)
            new_v = sharding.shard(new_v, "batch", "kv_seq", "kv_heads",
                                   None)
            attn_out = layers.attention(
                q, new_k, new_v, q_pos=positions, kv_pos=kv_pos,
                kv_valid=kv_valid, mode=mode, window=cfg.window,
                baos_calib=use_calib, kv_chunk=cfg.attn_chunk,
                unroll=cfg.unroll_layers, score_dtype=cfg.jscore_dtype)
            new_cache.update({"k": new_k, "v": new_v})
            if split:
                # warm step also refreshes the active buffer from the
                # just-written smoothed KV at the active-block offset
                L_act = lcache["k_act"].shape[1]
                act_start = calib_start if calib_start is not None \
                    else seg_start
                k_act = jax.lax.dynamic_slice(
                    ks, (zero, act_start - seg_start, zero, zero),
                    (ks.shape[0], L_act, ks.shape[2], ks.shape[3]))
                v_act = jax.lax.dynamic_slice(
                    vs, (zero, act_start - seg_start, zero, zero),
                    (vs.shape[0], L_act, vs.shape[2], vs.shape[3]))
                new_cache.update({
                    "k_act": k_act.astype(lcache["k_act"].dtype),
                    "v_act": v_act.astype(lcache["v_act"].dtype)})
    else:
        val = jnp.ones((B, S), bool)
        attn_out = layers.attention(
            q, k_seg, v_seg, q_pos=positions, kv_pos=positions,
            kv_valid=val, mode=mode, window=cfg.window,
            kv_chunk=cfg.attn_chunk, unroll=cfg.unroll_layers,
            score_dtype=cfg.jscore_dtype)
        new_cache = None

    attn_out = attn_out.reshape(B, S, cfg.n_heads * cfg.d_head)
    x = x + layers.qdot(attn_out, lp["attn"]["wo"], quant) * cfg.residual_scale
    x = sharding.shard(x, "batch", "seq", "embed")

    if cross_kv is not None:
        hx = _apply_norm(x, lp["ln_x"], cfg)
        qx = layers.qdot(hx, lp["xattn"]["wq"], quant).reshape(
            B, S, cfg.n_heads, cfg.d_head)
        ck, cv = cross_kv
        s_enc = ck.shape[1]
        xout = layers.attention(
            qx, ck, cv, q_pos=positions,
            kv_pos=jnp.arange(s_enc)[None, :].repeat(B, 0),
            kv_valid=jnp.ones((B, s_enc), bool), mode="bidir",
            kv_chunk=cfg.attn_chunk, unroll=cfg.unroll_layers)
        xout = xout.reshape(B, S, cfg.n_heads * cfg.d_head)
        x = x + layers.qdot(xout, lp["xattn"]["wo"], quant) * cfg.residual_scale

    h2 = _apply_norm(x, lp["ln2"], cfg)
    ffn_out, aux_l = _ffn(h2, lp, cfg, quant)
    aux = aux + aux_l
    x = x + ffn_out * cfg.residual_scale
    return sharding.shard(x, "batch", "seq", "embed"), new_cache, aux


def forward(params, cfg: ModelConfig, tokens: Optional[jax.Array] = None, *,
            embeds: Optional[jax.Array] = None,
            prefix_embeds: Optional[jax.Array] = None,
            cache=None, seg_start=0,
            kv_valid: Optional[jax.Array] = None,
            baos_cfg: Optional[baos_lib.BAOSConfig] = None,
            calibrate: bool = False,
            calib_mask: Optional[jax.Array] = None,
            quant: Optional[QuantPolicy] = None,
            cross_kv=None, attn_mode: Optional[str] = None,
            logits_slice: Optional[Tuple[int, int]] = None,
            head_mode: str = "logits"):
    calib_start = None
    if calibrate and logits_slice is not None:
        calib_start = jnp.asarray(logits_slice[0], jnp.int32)
    """Segment forward (paper Alg. 1).

    tokens (B, S_seg) or precomputed ``embeds``; with ``prefix_embeds``
    (VLM/audio stub frontends) they are prepended to the token embeddings.
    Returns (logits, new_cache, aux_loss).  ``head_mode='hidden'`` stops
    before the LM head and returns the final-norm hidden states (B, S, d)
    instead of logits — the feed for the fused head + Stable-Max sampling
    path (core/sampling.fused_head_stable_max), which streams the (d, V)
    projection so (B, S, V) logits never reach HBM.
    """
    baos_cfg = baos_cfg or baos_lib.BAOSConfig(enabled=False)
    if embeds is None:
        embeds = params["embed"][tokens] * cfg.embed_scale
    if prefix_embeds is not None:
        embeds = jnp.concatenate(
            [prefix_embeds.astype(embeds.dtype), embeds], axis=1)
    x = embeds.astype(cfg.jdtype)
    x = sharding.shard(x, "batch", "seq", "embed")
    B, S = x.shape[:2]

    if isinstance(seg_start, int):
        seg_start = jnp.int32(seg_start)
    positions = seg_start + jnp.arange(S, dtype=jnp.int32)[None, :]
    positions = jnp.broadcast_to(positions, (B, S))

    if cache is not None:
        s_tot = cache["k"].shape[2]
        kv_pos = jnp.broadcast_to(jnp.arange(s_tot, dtype=jnp.int32)[None, :],
                                  (B, s_tot))
        if kv_valid is None:
            kv_valid = jnp.ones((B, s_tot), bool)
    else:
        kv_pos = positions
        if kv_valid is None:
            kv_valid = jnp.ones((B, S), bool)

    def layer_fn(carry, xs):
        x, aux = carry
        lp, lcache = xs
        x, new_lcache, aux_l = _layer(
            x, lp, lcache, cfg, seg_start=seg_start, positions=positions,
            kv_valid=kv_valid, kv_pos=kv_pos, baos_cfg=baos_cfg,
            calibrate=calibrate, calib_mask=calib_mask, quant=quant,
            cross_kv=None, attn_mode=attn_mode, calib_start=calib_start)
        if new_lcache is None:
            new_lcache = 0  # placeholder ys
        return (x, aux + aux_l), new_lcache

    if cfg.remat != "none":
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat == "full"
                  else jax.checkpoint_policies.checkpoint_dots)
        layer_fn = jax.checkpoint(layer_fn, policy=policy)

    if cfg.unroll_layers:
        aux = jnp.float32(0)
        new_lcaches = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda t: t[i], params["layers"])
            lc = (jax.tree.map(lambda t: t[i], cache)
                  if cache is not None else None)
            ck = (jax.tree.map(lambda t: t[i], cross_kv)
                  if cross_kv is not None else None)
            x, nlc, aux_l = _layer(
                x, lp, lc, cfg, seg_start=seg_start, positions=positions,
                kv_valid=kv_valid, kv_pos=kv_pos, baos_cfg=baos_cfg,
                calibrate=calibrate, calib_mask=calib_mask, quant=quant,
                cross_kv=ck, attn_mode=attn_mode)
            aux = aux + aux_l
            new_lcaches.append(nlc)
        new_cache = (jax.tree.map(lambda *ls: jnp.stack(ls), *new_lcaches)
                     if cache is not None else None)
        x = _apply_norm(x, params["final_norm"], cfg)
        if logits_slice is not None:
            start, length = logits_slice
            x = jax.lax.dynamic_slice_in_dim(x, start, length, axis=1)
        if head_mode == "hidden":
            return sharding.shard(x, "batch", "seq", "embed"), new_cache, aux
        logits = layers.qdot(x, params["lm_head"], quant) * cfg.logit_scale
        logits = sharding.shard(logits, "batch", "seq", "vocab")
        return logits, new_cache, aux

    xs = (params["layers"], cache)
    if cross_kv is not None:
        # cross KV is per-layer stacked; fold into xs
        def layer_fn_x(carry, xs2):
            x, aux = carry
            lp, lcache, ck, cv = xs2
            x, new_lcache, aux_l = _layer(
                x, lp, lcache, cfg, seg_start=seg_start, positions=positions,
                kv_valid=kv_valid, kv_pos=kv_pos, baos_cfg=baos_cfg,
                calibrate=calibrate, calib_mask=calib_mask, quant=quant,
                cross_kv=(ck, cv), attn_mode=attn_mode,
                calib_start=calib_start)
            if new_lcache is None:
                new_lcache = 0
            return (x, aux + aux_l), new_lcache
        fn = layer_fn_x
        if cfg.remat != "none":
            fn = jax.checkpoint(fn, policy=(
                jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
                else jax.checkpoint_policies.checkpoint_dots))
        (x, aux), new_cache = jax.lax.scan(
            fn, (x, jnp.float32(0)), (params["layers"], cache,
                                      cross_kv[0], cross_kv[1]))
    else:
        (x, aux), new_cache = jax.lax.scan(layer_fn, (x, jnp.float32(0)), xs)

    x = _apply_norm(x, params["final_norm"], cfg)
    if logits_slice is not None:
        start, length = logits_slice
        x = jax.lax.dynamic_slice_in_dim(x, start, length, axis=1)
    if cache is None:
        new_cache = None
    if head_mode == "hidden":
        return sharding.shard(x, "batch", "seq", "embed"), new_cache, aux
    logits = layers.qdot(x, params["lm_head"], quant) * cfg.logit_scale
    logits = sharding.shard(logits, "batch", "seq", "vocab")
    return logits, new_cache, aux

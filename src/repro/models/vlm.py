"""InternVL2-style VLM: stub ViT frontend + dense LM backbone.

The vision tower is a STUB per the assignment: ``input_specs()`` supplies
precomputed patch embeddings (B, n_image_tokens, d_model) already projected
to the LM width.  The sequence layout reserves the first ``n_image_tokens``
positions as image placeholders; any *full-sequence* pass (train, warm step,
cache-free step) overwrites their embeddings with the image embeddings.
Refinement segments always start past the image prefix, so the text-only
path applies unchanged — blocked diffusion, BAOS, and the sampling engine
work exactly as for a dense LM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.transformer import ModelConfig


class VLMModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key):
        return transformer.init_params(key, self.cfg)

    def param_specs(self):
        return transformer.param_specs(self.cfg)

    def init_cache(self, batch: int, s_tot: int, act_len=None):
        return transformer.init_cache(self.cfg, batch, s_tot, act_len)

    def cache_specs(self, act_len=None):
        return transformer.cache_specs(self.cfg, act_len)

    def forward(self, params, tokens=None, *, image_embeds=None,
                embeds=None, **kw):
        cfg = self.cfg
        if embeds is None and tokens is not None:
            embeds = params["embed"][tokens] * cfg.embed_scale
            n_img = cfg.n_image_tokens
            if image_embeds is not None and embeds.shape[1] >= n_img > 0:
                # full-sequence pass: splice the stub ViT output over the
                # reserved placeholder positions (static slice).
                embeds = jnp.concatenate(
                    [image_embeds.astype(embeds.dtype),
                     embeds[:, n_img:]], axis=1)
        return transformer.forward(params, cfg, None, embeds=embeds, **kw)

"""Whisper-medium backbone: transformer encoder + diffusion-decodable decoder.

The conv audio frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, n_audio_ctx, d_model).  The
encoder runs once per request; its output feeds per-layer cross-attention
KV (computed once — the natural "frozen suffix" of the paper's dual-cache
idea).  The decoder is a standard dLLM stack (bidirectional self-attention,
blocked KV cache, BAOS) with cross-attention to the encoder.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import baos as baos_lib
from repro.models import layers, transformer
from repro.models.transformer import ModelConfig


class WhisperModel:
    """Enc-dec wrapper satisfying the shared forward contract (decoder-side).

    `forward` kwargs accept ``cross_kv`` (precomputed stacked per-layer
    encoder KV) — supplied by `encode()` once per request/batch.
    """

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.enc_cfg = dataclasses.replace(
            cfg, n_layers=cfg.n_encoder_layers, norm="ln", ffn="gelu",
            rope_theta=0.0, moe=None, window=None, attn_mode="bidir")

    def init(self, key):
        k_enc, k_dec, k_pos = jax.random.split(key, 3)
        dec = transformer.init_params(k_dec, self.cfg, cross_attn=True)
        enc_layers = jax.vmap(
            lambda k: transformer.init_layer_params(k, self.enc_cfg)
        )(jax.random.split(k_enc, self.enc_cfg.n_layers))
        dec["encoder"] = {
            "layers": enc_layers,
            "pos_embed": (jax.random.normal(
                k_pos, (self.cfg.n_audio_ctx, self.cfg.d_model)) * 0.01
            ).astype(self.cfg.jdtype),
            "final_norm": transformer._norm_params(
                self.cfg.d_model, "ln", self.cfg.jdtype),
        }
        return dec

    def param_specs(self):
        spec = transformer.param_specs(self.cfg, cross_attn=True)
        def stack(tree):
            return jax.tree.map(lambda s: ("layers",) + s, tree,
                                is_leaf=lambda x: isinstance(x, tuple))
        spec["encoder"] = {
            "layers": stack(transformer.layer_param_specs(self.enc_cfg)),
            "pos_embed": (None, "embed"),
            "final_norm": transformer._norm_specs("ln"),
        }
        return spec

    def init_cache(self, batch: int, s_tot: int, act_len=None):
        return transformer.init_cache(self.cfg, batch, s_tot, act_len)

    def cache_specs(self, act_len=None):
        return transformer.cache_specs(self.cfg, act_len)

    # -- encoder -------------------------------------------------------------
    def encode(self, params, audio_embeds: jax.Array) -> jax.Array:
        """audio_embeds: (B, n_audio_ctx, d) stub-frontend output."""
        cfg = self.enc_cfg
        x = (audio_embeds.astype(cfg.jdtype)
             + params["encoder"]["pos_embed"][None])
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))

        def layer_fn(carry, lp):
            x, = carry
            x, _, _ = transformer._layer(
                x, lp, None, cfg, seg_start=jnp.int32(0),
                positions=positions, kv_valid=jnp.ones((B, S), bool),
                kv_pos=positions, baos_cfg=baos_lib.BAOSConfig(enabled=False),
                calibrate=False, calib_mask=None, quant=None)
            return (x,), None

        if cfg.unroll_layers:
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda t: t[i], params["encoder"]["layers"])
                (x,), _ = layer_fn((x,), lp)
        else:
            (x,), _ = jax.lax.scan(layer_fn, (x,),
                                   params["encoder"]["layers"])
        return transformer._apply_norm(
            x, params["encoder"]["final_norm"], cfg)

    def cross_kv(self, params, enc_out: jax.Array):
        """Per-decoder-layer cross KV, computed once: (NL, B, S_enc, Hkv, D)."""
        cfg = self.cfg
        B, S = enc_out.shape[:2]

        def proj(lp):
            k = layers.qdot(enc_out, lp["xattn"]["wk"], None)
            v = layers.qdot(enc_out, lp["xattn"]["wv"], None)
            return (k.reshape(B, S, cfg.n_kv_heads, cfg.d_head),
                    v.reshape(B, S, cfg.n_kv_heads, cfg.d_head))

        return jax.vmap(proj)(params["layers"])

    def forward(self, params, tokens=None, **kw):
        return transformer.forward(params, self.cfg, tokens, **kw)

"""Transformer building blocks: norms, RoPE, MX-boundary GEMMs, and
memory-efficient *bidirectional* attention with BAOS fusion.

dLLM attention has no causal mask (paper §2.1): every position attends to
every other, so there is no triangular sparsity — instead we bound peak
memory with an online-softmax chunked scan over the KV sequence (the XLA
analogue of DART's FlashAttention engine; the Pallas version lives in
kernels/flash_bidir.py and is numerically cross-checked against this).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.core import baos as baos_lib
from repro.core import mx

# ---------------------------------------------------------------------------
# Quantization policy at GEMM boundaries (paper §3.1.1 asymmetric data path)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    enabled: bool = False
    weight_fmt: str = "mxint4"   # weights stored in HBM in MX format
    act_fmt: str = "mxint8"      # dynamic act quant at the systolic boundary

    def weights(self, w: jax.Array) -> jax.Array:
        if not self.enabled:
            return w
        # MX blocks run along the contraction (first) axis of (K, N) weights.
        return mx.mx_fake_quant(w, self.weight_fmt, axis=0)

    def acts(self, x: jax.Array) -> jax.Array:
        if not self.enabled:
            return x
        return mx.mx_fake_quant(x, self.act_fmt, axis=-1)


def qdot(x: jax.Array, w: jax.Array, policy: Optional[QuantPolicy],
         bias: Optional[jax.Array] = None) -> jax.Array:
    """x (..., K) @ w (K, N) with optional MX fake-quant at the boundary.
    Accumulation in f32 (the INT32-accumulate analogue), cast back to x.dtype."""
    if policy is not None and policy.enabled:
        x, w = policy.acts(x), policy.weights(w)
    y = jax.lax.dot_general(
        x, w.astype(x.dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Norms & activations
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


# ---------------------------------------------------------------------------
# Rotary position embedding (GPT-NeoX half-split convention)
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0
         ) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Bidirectional chunked attention (online softmax over KV chunks)
# ---------------------------------------------------------------------------

NEG_INF = jnp.float32(-1e30)


def _mask_bias(q_pos: jax.Array, kv_pos: jax.Array, kv_valid: jax.Array,
               mode: str, window: Optional[int]) -> jax.Array:
    """(B, Sq, Skv) additive bias: 0 allowed / -inf disallowed."""
    ok = kv_valid[:, None, :]
    qp, kp = q_pos[:, :, None], kv_pos[:, None, :]
    if mode == "causal":
        ok = ok & (kp <= qp)
    if window is not None:
        ok = ok & (jnp.abs(qp - kp) < window) if mode != "causal" else \
             ok & (qp - kp < window)
    return jnp.where(ok, 0.0, NEG_INF)


def attention_partials(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       q_pos: jax.Array, kv_pos: jax.Array,
                       kv_valid: jax.Array, mode: str = "bidir",
                       window: Optional[int] = None,
                       kv_chunk: int = 1024,
                       softmax_scale: Optional[float] = None,
                       unroll: bool = False,
                       score_dtype=jnp.float32):
    """Online-softmax partials: (m, l, o_unnorm), each (B, Hkv, G, Sq[, D]).

    Composable: partials from disjoint KV sources combine exactly (used by
    the split active-block cache).  ``score_dtype=bfloat16`` halves the
    materialized score/probability traffic (hillclimb option; max/sum
    accumulators stay f32)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    qg = (q * scale).reshape(B, Sq, Hkv, G, D)

    def chunk_scores(ks, kpos, kval):
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(score_dtype),
                       ks.astype(score_dtype),
                       preferred_element_type=score_dtype)
        bias = _mask_bias(q_pos, kpos, kval, mode, window)
        return s + bias[:, None, None, :, :].astype(score_dtype)

    def partial(ks, vs, kpos, kval):
        # every chunk-size tensor (s, p) stays in score_dtype; only the
        # (B,H,G,Sq)-sized accumulators are f32 (reductions use dtype= so
        # no full-size f32 copy is ever materialized)
        s = chunk_scores(ks, kpos, kval)
        m = jnp.maximum(jnp.max(s, axis=-1).astype(jnp.float32), NEG_INF)
        p = jnp.exp(s - m[..., None].astype(score_dtype))
        l = jnp.sum(p, axis=-1, dtype=jnp.float32)
        o = jnp.einsum("bhgqk,bkhd->bhgqd", p, vs.astype(score_dtype),
                       preferred_element_type=jnp.float32)
        return m, l, o.astype(jnp.float32)

    n_chunks = max(1, Skv // kv_chunk) if Skv % kv_chunk == 0 else 1
    if n_chunks <= 1:
        return partial(k, v, kv_pos, kv_valid)

    kc = k.reshape(B, n_chunks, kv_chunk, Hkv, D)
    vc = v.reshape(B, n_chunks, kv_chunk, Hkv, D)
    pc = kv_pos.reshape(B, n_chunks, kv_chunk)
    valc = kv_valid.reshape(B, n_chunks, kv_chunk)

    def body(carry, xs):
        ks, vs, kpos, kval = xs
        return combine_partials(carry, partial(ks, vs, kpos, kval)), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    o0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    xs = (kc.swapaxes(0, 1), vc.swapaxes(0, 1), pc.swapaxes(0, 1),
          valc.swapaxes(0, 1))
    if unroll:
        carry = (m0, l0, o0)
        for i in range(n_chunks):
            carry, _ = body(carry, jax.tree.map(lambda t: t[i], xs))
        return carry
    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), xs)
    return m, l, o


def combine_partials(a, b):
    """Exact online-softmax merge of two (m, l, o_unnorm) partials."""
    m_a, l_a, o_a = a
    m_b, l_b, o_b = b
    m = jnp.maximum(m_a, m_b)
    ca = jnp.exp(m_a - m)
    cb = jnp.exp(m_b - m)
    return m, l_a * ca + l_b * cb, o_a * ca[..., None] + o_b * cb[..., None]


def finalize_partials(p, B, Sq, Hq, D, dtype):
    m, l, o = p
    o = o / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D).astype(dtype)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              q_pos: jax.Array, kv_pos: jax.Array, kv_valid: jax.Array,
              mode: str = "bidir", window: Optional[int] = None,
              baos_calib: Optional[baos_lib.BAOSCalib] = None,
              kv_chunk: int = 1024, softmax_scale: Optional[float] = None,
              unroll: bool = False, score_dtype=jnp.float32,
              extra_kv=None) -> jax.Array:
    """Memory-efficient GQA attention.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D); kv_valid: (B, Skv) bool.
    When ``baos_calib`` is given, k/v are the *smoothed* cache entries: the
    inverse K-scale is fused into q and the V-scale/center corrections are
    applied on the output (exact identities — DESIGN.md §7.3-7.4).
    ``extra_kv=(k2, v2, pos2, valid2)`` adds a second KV source (the split
    active-block buffer) whose entries must live in the same smoothed space;
    partials from both sources merge exactly.
    """
    B, Sq, Hq, D = q.shape
    if baos_calib is not None:
        q = baos_lib.scale_query(q, baos_calib, Hq)

    p = attention_partials(
        q, k, v, q_pos=q_pos, kv_pos=kv_pos, kv_valid=kv_valid, mode=mode,
        window=window, kv_chunk=kv_chunk, softmax_scale=softmax_scale,
        unroll=unroll, score_dtype=score_dtype)
    if extra_kv is not None:
        k2, v2, pos2, valid2 = extra_kv
        p2 = attention_partials(
            q, k2, v2, q_pos=q_pos, kv_pos=pos2, kv_valid=valid2, mode=mode,
            window=window, kv_chunk=max(kv_chunk, k2.shape[1]),
            softmax_scale=softmax_scale, unroll=unroll,
            score_dtype=score_dtype)
        p = combine_partials(p, p2)

    out = finalize_partials(p, B, Sq, Hq, D, q.dtype)
    if baos_calib is not None:
        out = baos_lib.correct_output(out, baos_calib, Hq)
    return out


# ---------------------------------------------------------------------------
# Parameter init helpers
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, d_in: int, d_out: int,
               dtype=jnp.float32) -> jax.Array:
    std = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std
            ).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int, dtype=jnp.float32
               ) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


def shard_act(x: jax.Array, *names) -> jax.Array:
    return sharding.shard(x, *names)

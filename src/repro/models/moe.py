"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Supports the two assigned MoE archs (moonshot-v1-16b-a3b: 64e top-6;
qwen2-moe-a2.7b: 60 routed top-4 + shared experts) and the paper's
LLaDA-MoE track.

Dispatch is the sort-based formulation (tokens sorted by expert id, first-C
slots kept per expert) rather than the GShard one-hot einsum: the (T, E, C)
dispatch tensor is O(T^2) at production token counts, while sort-based is
O(T·K log T·K) with an (E, C, d) expert buffer — the only materialization
that scale actually allows.  Expert weights are TP-sharded along the FFN
hidden dim ("expert-TP"); tokens stay local to their data shard so routing
needs no cross-chip traffic, and each expert GEMM reduces over the model
axis exactly like a dense FFN.  (All-to-all expert parallelism is explored
as a §Perf hillclimb alternative.)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.models import layers


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0              # total shared-expert hidden size
    capacity_factor: float = 1.25
    norm_topk_prob: bool = True       # qwen-style renormalization
    router_aux_weight: float = 0.001
    # GShard-style grouped dispatch: sort/capacity per batch row so every
    # dispatch tensor keeps the batch dim and shards on the data axis.
    # False = single global group — O(global_tokens) buffers REPLICATED on
    # every device at production scale (43 GB/device for qwen2-moe
    # train_4k; kept only as the §Perf baseline/ablation).
    group_dispatch: bool = True


def init_moe_params(key: jax.Array, d_model: int, cfg: MoEConfig,
                    dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    E, F = cfg.num_experts, cfg.d_ff_expert
    std = (2.0 / (d_model + F)) ** 0.5
    p = {
        "router": layers.dense_init(ks[0], d_model, E, dtype),
        "w_gate": (jax.random.normal(ks[1], (E, d_model, F)) * std).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d_model, F)) * std).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, F, d_model)) * std).astype(dtype),
    }
    if cfg.num_shared_experts > 0:
        Fs = cfg.d_ff_shared or cfg.num_shared_experts * F
        p["shared"] = {
            "w_gate": layers.dense_init(ks[4], d_model, Fs, dtype),
            "w_up": layers.dense_init(ks[5], d_model, Fs, dtype),
            "w_down": layers.dense_init(ks[6], Fs, d_model, dtype),
            "gate_proj": layers.dense_init(ks[7], d_model, 1, dtype),
        }
    return p


def moe_param_specs(cfg: MoEConfig):
    p = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "mlp"),
        "w_up": ("experts", "embed", "mlp"),
        "w_down": ("experts", "mlp", "embed"),
    }
    if cfg.num_shared_experts > 0:
        p["shared"] = {
            "w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
            "w_down": ("mlp", "embed"), "gate_proj": ("embed", None),
        }
    return p


def route(x_flat: jax.Array, router_w: jax.Array, cfg: MoEConfig):
    """x_flat (T, d) -> (topk weights (T,K), topk experts (T,K), aux loss)."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_e = jax.lax.top_k(probs, cfg.top_k)
    if cfg.norm_topk_prob:
        topk_w = topk_w / jnp.sum(topk_w, axis=-1, keepdims=True)
    # Switch-style load-balance aux: E * sum_e fraction_e * prob_e
    E = cfg.num_experts
    assign = jax.nn.one_hot(topk_e[:, 0], E, dtype=jnp.float32)
    frac = jnp.mean(assign, axis=0)
    pmean = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * pmean)
    return topk_w, topk_e, aux


def moe_ffn(x: jax.Array, params, cfg: MoEConfig,
            quant: Optional[layers.QuantPolicy] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux loss scalar).

    Under a mesh, dispatch runs inside a *partial-manual* shard_map over
    the data axes: each data shard sorts/dispatches only its local tokens
    (one GShard group per shard), so every dispatch buffer is
    O(local_tokens) and no batched-gather ever crosses chips.  The model
    axis stays auto, so the expert GEMMs still reduce against the
    mlp-sharded weights exactly like a dense FFN.  Without a mesh (tests,
    single host) it falls back to per-row groups / one flat group.
    """
    from repro import sharding as shlib
    B, S, d = x.shape
    mesh = shlib.current_mesh()
    rules = shlib._ctx().rules if mesh is not None else {}
    batch_ax = rules.get("batch")
    if cfg.group_dispatch and B > 1:
        # spmd_axis_name pins every per-group dispatch tensor's group dim to
        # the data axes, so sort/gather/scatter buffers stay O(local tokens)
        # per chip instead of GSPMD replicating global-batch expert buffers.
        spmd = None
        if mesh is not None and batch_ax:
            axes = batch_ax if isinstance(batch_ax, tuple) else (batch_ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            if B % n == 0:
                spmd = axes if len(axes) > 1 else axes[0]
        out, aux = jax.vmap(
            lambda xb: _moe_tokens(xb[None], params, cfg, quant),
            spmd_axis_name=spmd)(x)
        out = sharding.shard(out.reshape(B, S, d), "batch", "seq", "embed")
        return out, jnp.mean(aux)
    return _moe_tokens(x.reshape(1, B * S, d), params, cfg, quant)


def _moe_tokens(x: jax.Array, params, cfg: MoEConfig,
                quant: Optional[layers.QuantPolicy] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """One dispatch group.  x: (1, T, d) -> (out (1, T, d), aux)."""
    _, T, d = x.shape
    B, S = 1, T
    K, E = cfg.top_k, cfg.num_experts
    C = max(1, int(-(-T * K // E) * cfg.capacity_factor))  # per-expert capacity

    xf = x.reshape(T, d)
    topk_w, topk_e, aux = route(xf, params["router"], cfg)

    # ---- sort-based dispatch -------------------------------------------
    P = T * K
    flat_e = topk_e.reshape(P)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]                                   # sorted expert ids
    starts = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos = jnp.arange(P) - starts[se]                     # position in expert
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)          # sentinel slot E*C
    tok = order // K                                     # source token / pair

    src = jnp.full((E * C + 1,), T, jnp.int32)           # sentinel token T
    src = src.at[slot].set(tok.astype(jnp.int32), mode="drop")
    x_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    expert_in = x_pad[src[: E * C]].reshape(E, C, d)

    # ---- expert FFN (SwiGLU), TP along the hidden dim -------------------
    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    if quant is not None and quant.enabled:
        wg = jax.vmap(lambda w: quant.weights(w))(wg)
        wu = jax.vmap(lambda w: quant.weights(w))(wu)
        wd = jax.vmap(lambda w: quant.weights(w))(wd)
        expert_in = quant.acts(expert_in)
    h = layers.swiglu(jnp.einsum("ecd,edf->ecf", expert_in, wg.astype(x.dtype)),
                      jnp.einsum("ecd,edf->ecf", expert_in, wu.astype(x.dtype)))
    expert_out = jnp.einsum("ecf,efd->ecd", h, wd.astype(x.dtype))

    # ---- combine ---------------------------------------------------------
    out_pad = jnp.concatenate(
        [expert_out.reshape(E * C, d), jnp.zeros((1, d), x.dtype)], axis=0)
    pair_sorted = out_pad[slot]                          # (P, d); dropped->0
    inv = jnp.argsort(order)
    pair = pair_sorted[inv].reshape(T, K, d)
    out = jnp.sum(pair * topk_w[..., None].astype(x.dtype), axis=1)

    # ---- shared experts --------------------------------------------------
    if cfg.num_shared_experts > 0:
        sp = params["shared"]
        hs = layers.swiglu(layers.qdot(xf, sp["w_gate"], quant),
                           layers.qdot(xf, sp["w_up"], quant))
        shared_out = layers.qdot(hs, sp["w_down"], quant)
        gate = jax.nn.sigmoid(
            jnp.einsum("td,do->to", xf.astype(jnp.float32),
                       sp["gate_proj"].astype(jnp.float32)))
        out = out + shared_out * gate.astype(x.dtype)

    return out.reshape(B, S, d), aux.astype(jnp.float32)


def moe_flops_per_token(d_model: int, cfg: MoEConfig) -> int:
    """Active-parameter FLOPs/token for the roofline MODEL_FLOPS term."""
    routed = cfg.top_k * 3 * d_model * cfg.d_ff_expert
    shared = 3 * d_model * (cfg.d_ff_shared or
                            cfg.num_shared_experts * cfg.d_ff_expert)
    return 2 * (routed + shared + d_model * cfg.num_experts)

"""Quickstart: blocked-diffusion text generation with the DART serving
stack (dual KV cache + BAOS MXINT4 cache + MXFP8 Stable-Max sampling).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import base
from repro.core import baos, diffusion, sampling
from repro.models.registry import build_model


def main():
    # any of the 12 registered archs works; smoke config runs on CPU
    cfg = base.get_config("llada-8b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: {cfg.name} ({cfg.family}), vocab={cfg.vocab}, "
          f"mask_id={cfg.mask_id}")

    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab - 2)

    dcfg = diffusion.DiffusionConfig(
        gen_length=32, block_length=8, steps_per_block=4,
        cache_mode="dual",                      # Fast-dLLM dual cache
        baos=baos.BAOSConfig(enabled=True, variant="minmax",
                             kv_format="mxint4"),
        sampling=sampling.SamplingConfig(fmt="mxfp8_e4m3"))

    out = diffusion.generate(model, params, prompt, dcfg,
                             rng=jax.random.PRNGKey(2))
    print("prompt :", prompt[0].tolist())
    print("output :", out[0, 16:].tolist())
    assert not bool(jnp.any(out[:, 16:] == cfg.mask_id)), "unmasking failed"
    print("OK — all positions committed over "
          f"{dcfg.num_blocks} blocks x {dcfg.steps_per_block} steps")


if __name__ == "__main__":
    main()

"""End-to-end dLLM training driver.

Trains a LLaDA-style masked-diffusion LM on the synthetic corpus with the
full production substrate: WSD schedule, async checkpointing, fault-
tolerant runtime, straggler watchdog.  The default fits a CPU smoke run;
``--d-model 512 --layers 12 --steps 300`` gives a ~100M-param run on real
hardware (the same code path the dry-run lowers at 512 chips).

    PYTHONPATH=src python examples/train_dllm.py --steps 40
"""
import argparse
import dataclasses

from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llada-8b")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    losses = train_cli.main([
        "--arch", args.arch, "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--ckpt-dir", "/tmp/repro_example_train"])
    assert losses[-1] < losses[0], "loss did not improve"
    print(f"loss improved {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()

"""BAOS ablation (paper Table 5 knobs): variant x alpha x KV format on a
trained tiny dLLM — generation agreement vs the BF16 reference.

    PYTHONPATH=src python examples/quant_ablation.py
"""
import jax
import numpy as np

from repro.configs import base
from repro.core import baos as baos_lib
from repro.core import diffusion, sampling
from repro.data.pipeline import motif_pool_batch
from repro.models.registry import build_model
from repro.optim import adamw


def main():
    cfg = base.get_config("llada-8b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.OptConfig(lr=1e-2, schedule="const", warmup_steps=10)
    state = adamw.init_state(params)

    @jax.jit
    def step(p, s, toks, i):
        rng = jax.random.fold_in(jax.random.PRNGKey(1), i)
        (loss, _), g = jax.value_and_grad(
            lambda pp: diffusion.masked_diffusion_loss(model, pp, toks, rng),
            has_aux=True)(p)
        p, s, _ = adamw.apply_updates(p, g, s, opt)
        return p, s, loss

    print("training tiny dLLM on motif corpus ...")
    for i in range(250):
        params, state, loss = step(
            params, state, motif_pool_batch(i, vocab=cfg.vocab), i)
    print(f"final loss {float(loss):.3f}")

    prompt = motif_pool_batch(999, vocab=cfg.vocab)[:4, :32]
    target = np.tile(np.asarray(prompt[:, :4]), (1, 4))

    def gen(bcfg, fmt="mxfp8_e4m3"):
        d = diffusion.DiffusionConfig(
            gen_length=16, block_length=8, steps_per_block=4,
            cache_mode="dual", baos=bcfg,
            sampling=sampling.SamplingConfig(fmt=fmt))
        return np.asarray(diffusion.generate(
            model, params, prompt, d, rng=jax.random.PRNGKey(3))[:, 32:])

    ref = gen(baos_lib.BAOSConfig(enabled=False), "none")
    print(f"{'config':34s} task_acc  agreement")
    print(f"{'bf16 reference':34s} {(ref == target).mean():.3f}     1.000")
    for variant in ["mean", "minmax"]:
        for alpha in [1.0, 0.9, 0.6]:
            for fmt in ["mxint4"]:
                b = baos_lib.BAOSConfig(enabled=True, variant=variant,
                                        alpha=alpha, kv_format=fmt)
                o = gen(b)
                name = f"baos {variant} a={alpha} kv={fmt}"
                print(f"{name:34s} {(o == target).mean():.3f}     "
                      f"{(o == ref).mean():.3f}")
    # naive KV4 baseline = alpha 0 disables adaptive scaling
    o = gen(baos_lib.BAOSConfig(enabled=True, alpha=0.0, kv_format="mxint4"))
    print(f"{'naive kv4 (alpha=0)':34s} {(o == target).mean():.3f}     "
          f"{(o == ref).mean():.3f}")


if __name__ == "__main__":
    main()

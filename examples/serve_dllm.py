"""Batched-request dLLM serving with per-cache-mode comparison.

Serves synthetic batched requests through all three KV-cache strategies
(none / prefix / dual — paper Fig. 4) and prints the TPS ordering the
paper's Table 6 documents, plus the DART quantization stack effect.

    PYTHONPATH=src python examples/serve_dllm.py
"""
from repro.launch import serve as serve_cli


def main():
    for cache in ["none", "prefix", "dual"]:
        print(f"\n=== cache mode: {cache} ===")
        serve_cli.main([
            "--arch", "llada-8b", "--batch", "2", "--prompt-len", "16",
            "--gen-len", "32", "--block-len", "16", "--steps", "4",
            "--cache", cache, "--requests", "2"])
    print("\n=== dual + no quantization (BF16 reference) ===")
    serve_cli.main([
        "--arch", "llada-8b", "--batch", "2", "--prompt-len", "16",
        "--gen-len", "32", "--block-len", "16", "--steps", "4",
        "--cache", "dual", "--no-baos", "--sampling-fmt", "none",
        "--requests", "2"])


if __name__ == "__main__":
    main()

"""Continuous-batching dLLM serving demo.

Drives the serving engine (repro.serving) over a mixed-length synthetic
request trace under each scheduler policy, prints the latency/throughput/
occupancy summary, then runs the legacy one-batch-at-a-time path and the
per-cache-mode comparison (paper Fig. 4 / Table 6) for reference.

    PYTHONPATH=src python examples/serve_dllm.py
"""
from repro.launch import serve as serve_cli

ENGINE_BASE = [
    "--arch", "llada-8b", "--batch", "2", "--prompt-len", "16",
    "--gen-len", "32", "--block-len", "8", "--steps", "4",
    "--requests", "3", "--mixed",
]


def main():
    for policy in ["fifo", "sgf", "slowfast"]:
        print(f"\n=== engine: policy={policy} (warm ticks, mixed lengths) ===")
        serve_cli.main(ENGINE_BASE + ["--policy", policy])

    print("\n=== engine: per-stage breakdown (Fig. 1 serving analogue) ===")
    serve_cli.main(ENGINE_BASE + ["--breakdown"])

    for cache in ["none", "prefix", "dual"]:
        print(f"\n=== legacy: cache mode {cache} ===")
        serve_cli.main([
            "--legacy", "--arch", "llada-8b", "--batch", "2",
            "--prompt-len", "16", "--gen-len", "32", "--block-len", "16",
            "--steps", "4", "--cache", cache, "--requests", "2"])

    print("\n=== legacy: dual + no quantization (BF16 reference) ===")
    serve_cli.main([
        "--legacy", "--arch", "llada-8b", "--batch", "2", "--prompt-len",
        "16", "--gen-len", "32", "--block-len", "16", "--steps", "4",
        "--cache", "dual", "--no-baos", "--sampling-fmt", "none",
        "--requests", "2"])


if __name__ == "__main__":
    main()

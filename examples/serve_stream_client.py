"""Streaming-frontend quickstart: boot a 2-replica server in-process,
stream one completion over SSE, print the dLLM-native commit events, then
push a short burst to show 429/overloaded backpressure.

The curl equivalent of the streamed request (against
``python -m repro.launch.serve --arch llada-8b --http 8080``):

    curl -N -X POST http://127.0.0.1:8080/v1/completions \
        -H 'Content-Type: application/json' \
        -d '{"prompt": [5, 17, 9, 2], "max_tokens": 16, "stream": true}'

    PYTHONPATH=src python examples/serve_stream_client.py
"""
import asyncio

import jax
import numpy as np

from repro.configs import base
from repro.core import diffusion
from repro.models.registry import build_model
from repro.serving.frontend import build_frontend, loadgen


async def main_async():
    cfg = base.get_config("llada-8b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dcfg = diffusion.DiffusionConfig(gen_length=16, block_length=8,
                                     steps_per_block=4, cache_mode="none")
    frontend = build_frontend(model, params, dcfg, model_name="llada-8b",
                              replicas=2, num_slots=2, max_seq_len=48,
                              mode="none", max_queue=1)
    await frontend.start()
    print(f"serving on {frontend.url} "
          f"(2 replicas, least-loaded routing)\n")

    prompt = np.random.RandomState(0).randint(
        0, cfg.vocab - 2, size=(12,)).tolist()
    print("=== one streamed request (tokens commit out of order "
          "within each block) ===")
    row = await loadgen.complete(frontend.url, prompt, 16)
    print(f"ticks seen: {row['ticks']}  (monotone: "
          f"{row['ticks_monotone']})")
    print(f"commit order of positions: {row['positions']}")
    print(f"final text (token-id string): {row['text']}\n")

    print("=== burst of 8 requests against 2x2 slots + max_queue=1: "
          "excess sheds with 429 ===")
    rows = await asyncio.gather(*[
        loadgen.complete(frontend.url, prompt, 16) for _ in range(8)])
    counts = {}
    for r in rows:
        counts[r["status"]] = counts.get(r["status"], 0) + 1
    print(f"statuses: {counts}")
    stats = await loadgen.get_json(frontend.url, "/v1/stats")
    for rep in stats["replicas"]:
        print(f"{rep['name']}: completed={rep['completed']} "
              f"shed={rep['shed']}")
    await frontend.shutdown()          # graceful drain


if __name__ == "__main__":
    asyncio.run(main_async())
